"""Shared benchmark utilities: timing + CSV emission + JSON artifacts."""

from __future__ import annotations

import datetime
import json
import subprocess
import time
from pathlib import Path
from typing import Callable

import jax

ROWS: list[tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header() -> None:
    print("name,us_per_call,derived", flush=True)


def repo_sha() -> str:
    """HEAD commit of the repo the benchmark ran in ('' outside git)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return ""


def write_bench_json(json_dir: str | Path, suite: str,
                     rows: list[tuple[str, float, str]],
                     wall_s: float, failed: bool) -> Path:
    """One ``BENCH_<suite>.json`` artifact per section (CI uploads these
    so run-over-run regressions are diffable without re-parsing logs)."""
    out = Path(json_dir) / f"BENCH_{suite}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "suite": suite,
        "sha": repo_sha(),
        "created": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "wall_s": round(wall_s, 3),
        "failed": failed,
        "rows": [{"name": n, "us_per_call": round(us, 3), "derived": d}
                 for n, us, d in rows],
    }
    out.write_text(json.dumps(doc, indent=1) + "\n")
    return out
