"""Benchmark driver — one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows:
  * graphdiff_bench      — Fig. 4 (graph-difference transfer + encoder
                           throughput + sharded streaming)
  * scaling_bench        — Fig. 5 strong scaling + Fig. 7 weak scaling
                           (+ the elastic ``rescale`` smoke row: re-shard
                           payload bytes + time-to-recompose; + the
                           out-of-core ``sampled`` smoke row: full-graph
                           budget refusals vs a sampled run that fits)
  * partition_compare    — Table 2 (snapshot vs hypergraph vertex part.)
  * checkpoint_bench     — §3.1/§6.2 (memory/time vs nb)
  * kernel_bench         — hot-spot op microbenchmarks
  * overlap_bench        — §6.5 compute/comm + stream transfer overlap
  * serve_bench          — online serving: warm vs cold query latency
                           (p50/p95 at batch 1/8/64) + live-ingest
                           events/s
  * obs_bench            — repro.obs tracer overhead: asserts the
                           disabled tracer costs <2% on a hot loop, and
                           reports the enabled-tracer cost for scale

``--smoke`` runs tiny shapes (the CI smoke job); ``--only a,b`` restricts
to named sections; ``--json-dir DIR`` additionally writes one
``BENCH_<section>.json`` artifact per section (suite name, repo SHA,
wall time, the CSV rows) for CI upload.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common
from benchmarks.common import header


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--only", default="",
                    help="comma-separated section names to run")
    ap.add_argument("--json-dir", default="",
                    help="write one BENCH_<section>.json artifact per "
                         "section into this directory")
    args = ap.parse_args()

    header()
    from benchmarks import (checkpoint_bench, graphdiff_bench, kernel_bench,
                            obs_bench, overlap_bench, partition_compare,
                            scaling_bench, serve_bench)
    smoke = args.smoke
    sections = [
        ("graphdiff", lambda: graphdiff_bench.run(
            **({"n": 256, "t": 12} if smoke else {}))),
        ("scaling", scaling_bench.run),
        ("rescale", lambda: scaling_bench.rescale_smoke(
            **({"n": 32, "t": 8} if smoke else {}))),
        ("compressed", lambda: scaling_bench.compressed_round(
            **({"n": 64, "t": 16} if smoke else {}))),
        ("sampled", lambda: scaling_bench.sampled_smoke(
            **({"n": 192, "t": 8} if smoke else {}))),
        ("partition_compare", partition_compare.run),
        ("checkpoint", lambda: checkpoint_bench.run(
            **({"n": 128, "t": 16} if smoke else {}))),
        ("kernels", kernel_bench.run),
        ("overlap", lambda: overlap_bench.run(smoke=smoke)),
        ("serve", lambda: serve_bench.run(
            **({"n": 96, "windows": 12, "events": 1200,
                "batches": (1, 8), "iters": 4} if smoke else {}))),
        ("obs", lambda: obs_bench.run(
            **({"units": 200, "reps": 3} if smoke else {}))),
    ]
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    if only:
        unknown = only - {name for name, _ in sections}
        if unknown:
            raise SystemExit(f"unknown sections: {sorted(unknown)}")
        sections = [(n, f) for n, f in sections if n in only]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        first_row = len(common.ROWS)
        t0 = time.perf_counter()
        failed = False
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            failed = True
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
        if args.json_dir:
            common.write_bench_json(args.json_dir, name,
                                    common.ROWS[first_row:],
                                    time.perf_counter() - t0, failed)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
