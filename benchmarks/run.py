"""Benchmark driver — one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows:
  * graphdiff_bench      — Fig. 4 (graph-difference transfer)
  * scaling_bench        — Fig. 5 strong scaling + Fig. 7 weak scaling
  * partition_compare    — Table 2 (snapshot vs hypergraph vertex part.)
  * checkpoint_bench     — §3.1/§6.2 (memory/time vs nb)
  * kernel_bench         — hot-spot op microbenchmarks
  * overlap_bench        — §6.5 compute/comm overlap (beyond-paper)
"""

from __future__ import annotations

import sys
import traceback

from benchmarks.common import header


def main() -> None:
    header()
    from benchmarks import (checkpoint_bench, graphdiff_bench, kernel_bench,
                            overlap_bench, partition_compare, scaling_bench)
    sections = [
        ("graphdiff", graphdiff_bench.run),
        ("scaling", scaling_bench.run),
        ("partition_compare", partition_compare.run),
        ("checkpoint", checkpoint_bench.run),
        ("kernels", kernel_bench.run),
        ("overlap", overlap_bench.run),
    ]
    failures = 0
    for name, fn in sections:
        print(f"# --- {name} ---", flush=True)
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
