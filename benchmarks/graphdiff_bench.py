"""Paper Fig. 4: graph-difference vs naive snapshot transfer.

Reports, per (model x smoothing) configuration and churn level:
  * bytes shipped per epoch (exact, from the delta encoding),
  * the transfer-time reduction factor implied on a PCIe16-class link,
  * measured on-device reconstruction cost (the price GD pays),
  * the beyond-paper variant: recompute edge VALUES on device (Laplacian
    weights are degree-derived), shipping only index deltas,
  * encoder throughput: the vectorized ``repro.stream`` encoder vs the
    reference dict-based encoder (same output, measured speedup),
  * shard-aware streaming: per-shard time-slice payloads vs broadcast.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import graphdiff, smoothing
from repro.graph import generate
from repro.stream import encoder as stream_encoder
from repro.stream import sharded as stream_sharded


def encoder_throughput(n: int = 2048, t: int = 32, density: float = 3.0,
                       churn: float = 0.2, iters: int = 3) -> None:
    """Host encode wall-time: reference dict encoder vs vectorized."""
    snaps = generate.evolving_dynamic_graph(n, t, density, churn, seed=0)
    rng = np.random.default_rng(0)
    values = [rng.uniform(0.5, 1.5, s.shape[0]).astype(np.float32)
              for s in snaps]
    max_edges = stream_encoder.padded_max_edges(snaps)
    stats = stream_encoder.measure_stats(snaps, n, 8, max_edges)
    edges_total = sum(s.shape[0] for s in snaps)

    def timed(fn):
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_ref = timed(lambda: graphdiff.encode_stream(snaps, values, n,
                                                  max_edges, 8))
    t_fast = timed(lambda: stream_encoder.encode_stream_fast(
        snaps, values, n, max_edges, 8, stats))
    record("graphdiff/encoder/dict_reference", t_ref * 1e6,
           f"{edges_total / t_ref / 1e6:.2f} Medges/s")
    record("graphdiff/encoder/vectorized", t_fast * 1e6,
           f"{edges_total / t_fast / 1e6:.2f} Medges/s "
           f"speedup={t_ref / t_fast:.1f}x")


def sharded_payloads(n: int = 2048, t: int = 32, density: float = 3.0,
                     churn: float = 0.1) -> None:
    """Per-shard time-slice payloads under snapshot partitioning."""
    snaps = generate.evolving_dynamic_graph(n, t, density, churn, seed=0)
    max_edges = stream_encoder.padded_max_edges(snaps)
    stream = stream_encoder.encode_stream_fast(snaps, None, n, max_edges, 8)
    total = graphdiff.stream_bytes(stream)
    for p in (2, 4):
        shards = stream_sharded.encode_time_sliced(snaps, None, n,
                                                   max_edges, 8, p)
        per_shard = max(sum(i.payload_bytes for i in s) for s in shards)
        record(f"graphdiff/sharded/P{p}", 0.0,
               f"max_shard_bytes={per_shard} broadcast={total} "
               f"reduction={total / max(per_shard, 1):.2f}x")


def run(n: int = 2048, t: int = 32, density: float = 3.0) -> None:
    encoder_throughput(n, t, density)
    sharded_payloads(n, t, density)
    for model, smooth in (("cdgcn", "none"), ("evolvegcn", "edgelife"),
                          ("tmgcn", "mproduct")):
        for churn in (0.05, 0.2):
            snaps = generate.evolving_dynamic_graph(n, t, density, churn,
                                                    seed=0)
            values = None
            if smooth == "edgelife":
                snaps, values = smoothing.edge_life(snaps, 5)
            elif smooth == "mproduct":
                snaps, values = smoothing.m_transform_sparse(snaps, 5)
            max_edges = stream_encoder.padded_max_edges(snaps)
            stream = stream_encoder.encode_stream_fast(
                snaps, values, n, max_edges, block_size=8)
            gd = graphdiff.stream_bytes(stream)
            naive = graphdiff.naive_bytes(snaps)
            record(f"graphdiff/{model}/churn{churn}/bytes_ratio",
                   0.0, f"gd={gd} naive={naive} x{naive / gd:.2f}")
            # beyond-paper: values recomputed on device -> index deltas only
            idx_only = sum(
                (int(s.drop_mask.sum()) * 4 + int(s.add_mask.sum()) * 8)
                if isinstance(s, graphdiff.SnapshotDelta)
                else s.num_edges * 8 for s in stream)
            record(f"graphdiff/{model}/churn{churn}/values_on_device",
                   0.0, f"idx_only={idx_only} x{naive / max(idx_only,1):.2f}")
            # reconstruction cost (device-side apply_delta)
            delta = next(s for s in stream
                         if isinstance(s, graphdiff.SnapshotDelta))
            full = next(s for s in stream
                        if isinstance(s, graphdiff.FullSnapshot))
            apply_jit = jax.jit(graphdiff.apply_delta)
            us = time_fn(apply_jit, jnp.asarray(full.edges),
                         jnp.asarray(full.mask),
                         jnp.asarray(delta.drop_pos),
                         jnp.asarray(delta.drop_mask),
                         jnp.asarray(delta.add_edges),
                         jnp.asarray(delta.add_mask))
            record(f"graphdiff/{model}/churn{churn}/reconstruct", us,
                   f"E={max_edges}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
