"""Paper Fig. 4: graph-difference vs naive snapshot transfer.

Reports, per (model x smoothing) configuration and churn level:
  * bytes shipped per epoch (exact, from the delta encoding),
  * the transfer-time reduction factor implied on a PCIe16-class link,
  * measured on-device reconstruction cost (the price GD pays),
  * the beyond-paper variant: recompute edge VALUES on device (Laplacian
    weights are degree-derived), shipping only index deltas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import graphdiff, smoothing
from repro.graph import generate


def run(n: int = 2048, t: int = 32, density: float = 3.0) -> None:
    for model, smooth in (("cdgcn", "none"), ("evolvegcn", "edgelife"),
                          ("tmgcn", "mproduct")):
        for churn in (0.05, 0.2):
            snaps = generate.evolving_dynamic_graph(n, t, density, churn,
                                                    seed=0)
            values = None
            if smooth == "edgelife":
                snaps, values = smoothing.edge_life(snaps, 5)
            elif smooth == "mproduct":
                snaps, values = smoothing.m_transform_sparse(snaps, 5)
            max_edges = max(s.shape[0] for s in snaps)
            max_edges = ((max_edges + 127) // 128) * 128
            stream = graphdiff.encode_stream(snaps, values, n, max_edges,
                                             block_size=8)
            gd = graphdiff.stream_bytes(stream)
            naive = graphdiff.naive_bytes(snaps)
            record(f"graphdiff/{model}/churn{churn}/bytes_ratio",
                   0.0, f"gd={gd} naive={naive} x{naive / gd:.2f}")
            # beyond-paper: values recomputed on device -> index deltas only
            idx_only = sum(
                (int(s.drop_mask.sum()) * 4 + int(s.add_mask.sum()) * 8)
                if isinstance(s, graphdiff.SnapshotDelta)
                else s.num_edges * 8 for s in stream)
            record(f"graphdiff/{model}/churn{churn}/values_on_device",
                   0.0, f"idx_only={idx_only} x{naive / max(idx_only,1):.2f}")
            # reconstruction cost (device-side apply_delta)
            delta = next(s for s in stream
                         if isinstance(s, graphdiff.SnapshotDelta))
            full = next(s for s in stream
                        if isinstance(s, graphdiff.FullSnapshot))
            apply_jit = jax.jit(graphdiff.apply_delta)
            us = time_fn(apply_jit, jnp.asarray(full.edges),
                         jnp.asarray(full.mask),
                         jnp.asarray(delta.drop_pos),
                         jnp.asarray(delta.drop_mask),
                         jnp.asarray(delta.add_edges),
                         jnp.asarray(delta.add_mask))
            record(f"graphdiff/{model}/churn{churn}/reconstruct", us,
                   f"E={max_edges}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
