"""Kernel-level microbenchmarks: the XLA-native paths that the Pallas
kernels replace on TPU, timed on CPU for regression tracking, plus roofline
byte/flop accounting per kernel call."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import temporal
from repro.graph import segment


def run() -> None:
    rng = np.random.default_rng(0)
    # SpMM (GCN aggregate)
    for (n, e, f) in ((10_000, 100_000, 64), (50_000, 500_000, 128)):
        edges = jnp.asarray(rng.integers(0, n, (e, 2)), jnp.int32)
        w = jnp.asarray(rng.normal(size=(e,)).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
        fn = jax.jit(lambda x_, e_, w_, n=n: segment.spmm(x_, e_, w_, n))
        us = time_fn(fn, x, edges, w)
        flops = 2 * e * f
        record(f"spmm/n{n}_e{e}_f{f}", us,
               f"gflops={flops / us / 1e3:.2f} bytes={e * f * 8 + n * f * 4}")
    # M-product
    for (t, n, f, w_) in ((64, 4096, 16, 5), (256, 1024, 16, 9)):
        x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
        fn = jax.jit(lambda x_, w_=w_: temporal.m_product(x_, w_))
        us = time_fn(fn, x)
        record(f"mproduct/t{t}_n{n}_f{f}_w{w_}", us, "")
    # LSTM over timeline
    for (t, n, f, h) in ((64, 4096, 16, 16),):
        p = temporal.init_lstm_params(jax.random.PRNGKey(0), f, h)
        x = jnp.asarray(rng.normal(size=(t, n, f)).astype(np.float32))
        fn = jax.jit(lambda x_: temporal.lstm_scan(p, x_)[0])
        us = time_fn(fn, x)
        flops = t * 2 * n * (f + h) * 4 * h
        record(f"lstm/t{t}_n{n}", us, f"gflops={flops / us / 1e3:.2f}")
    # decode attention (jnp path used by serve cells)
    from repro.kernels.flash_decode import ops as fd
    b, hq, kvh, d, s = 4, 16, 4, 64, 8192
    q = jnp.asarray(rng.normal(size=(b, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, s, kvh, d)).astype(np.float32))
    clen = jnp.full((b,), s, jnp.int32)
    fn = jax.jit(lambda *a: fd.flash_decode_ref(*a))
    us = time_fn(fn, q, k, v, clen)
    bytes_kv = 2 * b * s * kvh * d * 4
    record(f"decode_attn/s{s}", us, f"kv_bytes={bytes_kv}")


if __name__ == "__main__":
    from benchmarks.common import header
    header()
    run()
