#!/usr/bin/env python
"""Trace checker: schema-validate an exported ``--trace`` file and
assert the spans a healthy run must contain.

CI's trace-smoke step runs a short streamed_mesh fit with ``--trace``
and then gates on this script: the trace must be a valid Chrome-trace /
Perfetto file (``repro.obs.validate_trace``), every completed round must
carry all four round-phase spans (``round.transfer`` / ``round.spatial``
/ ``round.a2a`` / ``round.temporal`` — the phases
``round_time_model`` predicts), and any ``--require``'d span names
(e.g. the prefetch staging threads) must be present.

Usage::

    python tools/check_trace.py trace.json \
        --phases --require prefetch.stage --require prefetch.wait

Exits non-zero with one line per problem.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs import PHASES, load_trace, validate_trace  # noqa: E402


def check(path: str, require: list[str], phases: bool) -> list[str]:
    events, meta = load_trace(path)
    problems = [f"{path}: {p}" for p in validate_trace(events)]
    if problems:
        return problems
    names = {ev["name"] for ev in events}
    for name in require:
        if name not in names:
            problems.append(f"{path}: required span {name!r} missing "
                            f"(have {sorted(names)})")
    if phases:
        rounds = sorted({ev["args"]["round"] for ev in events
                         if ev["name"] == "round"
                         and "round" in ev.get("args", {})})
        if not rounds:
            problems.append(f"{path}: no 'round' spans — not a traced "
                            "streamed run?")
        for r in rounds:
            have = {ev["name"] for ev in events
                    if ev.get("args", {}).get("round") == r}
            missing = [f"round.{p}" for p in PHASES
                       if f"round.{p}" not in have]
            # the last round may be cut off mid-flight (preemption /
            # stop_fn) — phases are derived after the step completes
            if missing and r != rounds[-1]:
                problems.append(f"{path}: round {r} missing phase spans "
                                f"{missing}")
        if len(rounds) >= 2 and meta.get("dropped_spans", 0) == 0:
            # with no ring overflow, every complete round must be whole —
            # including the last one when the run wasn't cut short
            have = {ev["name"] for ev in events
                    if ev.get("args", {}).get("round") == rounds[-1]}
            missing = [f"round.{p}" for p in PHASES
                       if f"round.{p}" not in have]
            if missing:
                problems.append(f"{path}: final round {rounds[-1]} missing "
                                f"phase spans {missing}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace file written by --trace")
    ap.add_argument("--require", action="append", default=[],
                    metavar="NAME", help="span name that must be present "
                    "(repeatable)")
    ap.add_argument("--phases", action="store_true",
                    help="assert all four round_time_model phase spans "
                    "(transfer/spatial/a2a/temporal) on every round")
    args = ap.parse_args()
    problems = check(args.trace, args.require, args.phases)
    for p in problems:
        print(p, file=sys.stderr)
    if not problems:
        events, _ = load_trace(args.trace)
        rounds = {ev["args"]["round"] for ev in events
                  if ev["name"] == "round" and "round" in ev.get("args", {})}
        print(f"{args.trace}: OK ({len(events)} events, "
              f"{len(rounds)} rounds)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
