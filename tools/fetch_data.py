#!/usr/bin/env python
"""Fetch + preprocess the paper's real DTDG traces (KONECT edge lists).

The paper evaluates on temporal edge lists (epinions, flickr, youtube)
that ship as KONECT archives: ``%``-commented text with
``src dst [weight [timestamp]]`` rows and 1-based vertex ids.  This tool
turns one of those into the repo's timestamped edge-list format
(``src dst t`` rows, consecutive integer time bins — exactly what
``repro.run.EdgeListDTDG`` loads, out-of-core via ``chunk_edges``):

    python tools/fetch_data.py fetch --dataset epinions --dest data/
    python tools/fetch_data.py preprocess --dataset epinions \\
        --raw data/out.soc-sign-epinions --out data/epinions.tsv \\
        --num-steps 32

Checksums: every download is sha256-verified.  The registry pin is
trust-on-first-use — the first fetch records the digest in a
``<archive>.sha256`` sidecar next to the download (and prints it, so it
can be pinned in ``DATASETS``); later fetches refuse a mismatch.
``--expect-sha256`` overrides both.

Offline fixture: CI has no network, so the committed test fixture
(``tests/fixtures/epinions_tiny.tsv``) is derived by the SAME
``parse_konect -> sub_slice -> bin_timestamps`` path from the
deterministic KONECT-format sample written by the ``sample`` subcommand
(a format-faithful stand-in for the real archive).  Regenerate with:

    python tools/fetch_data.py sample --out /tmp/out.epinions-sample
    python tools/fetch_data.py fixture --raw /tmp/out.epinions-sample \\
        --out tests/fixtures/epinions_tiny.tsv --num-nodes 24 \\
        --num-steps 8

Against a real fetched archive, ``fixture --raw data/out.<name>`` cuts
the analogous deterministic sub-slice of the genuine trace.
"""

from __future__ import annotations

import argparse
import hashlib
import sys
import tarfile
from dataclasses import dataclass
from pathlib import Path

import numpy as np

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    url: str
    member: str                 # path of the edge list inside the archive
    sha256: str | None = None   # pin (None = trust-on-first-use sidecar)


DATASETS = {
    "epinions": DatasetSpec(
        name="epinions",
        url="http://konect.cc/files/download.tsv.soc-sign-epinions.tar.bz2",
        member="soc-sign-epinions/out.soc-sign-epinions"),
    "youtube": DatasetSpec(
        name="youtube",
        url="http://konect.cc/files/download.tsv.youtube-u-growth.tar.bz2",
        member="youtube-u-growth/out.youtube-u-growth"),
}


# ------------------------------------------------------------ checksum -----

def sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def verify_checksum(path: Path, expect: str | None,
                    pin: str | None) -> str:
    """sha256-verify ``path`` against (in priority order) the CLI
    ``expect``, the registry ``pin``, or the trust-on-first-use sidecar
    ``<path>.sha256`` (created when none of the above exist)."""
    digest = sha256_file(path)
    sidecar = path.with_suffix(path.suffix + ".sha256")
    want = expect or pin
    if want is None and sidecar.exists():
        want = sidecar.read_text().split()[0]
    if want is None:
        sidecar.write_text(f"{digest}  {path.name}\n")
        print(f"recorded sha256 {digest} -> {sidecar.name} "
              "(pin this in DATASETS)")
        return digest
    if digest != want:
        raise SystemExit(f"checksum mismatch for {path}:\n"
                         f"  expected {want}\n  got      {digest}")
    print(f"sha256 OK: {digest}")
    return digest


# ------------------------------------------------------------- fetch -------

def fetch(spec: DatasetSpec, dest_dir: Path,
          expect_sha256: str | None = None) -> Path:
    """Download + verify + extract; returns the raw edge-list path."""
    import urllib.request

    dest_dir.mkdir(parents=True, exist_ok=True)
    archive = dest_dir / spec.url.rsplit("/", 1)[-1]
    if not archive.exists():
        print(f"downloading {spec.url}")
        urllib.request.urlretrieve(spec.url, archive)
    verify_checksum(archive, expect_sha256, spec.sha256)
    raw = dest_dir / Path(spec.member).name
    if not raw.exists():
        with tarfile.open(archive) as tf:
            member = tf.getmember(spec.member)
            member.name = Path(spec.member).name     # no nested dirs
            tf.extract(member, dest_dir, filter="data")
    return raw


# -------------------------------------------------------- preprocess -------

def parse_konect(path: Path) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """KONECT rows -> (src, dst, timestamp) int64 arrays, file order.

    Rows are ``src dst [weight [timestamp]]``; ``%`` lines are comments.
    Rows without a timestamp column are dropped (the DTDG needs one).
    """
    srcs, dsts, times = [], [], []
    with open(path) as f:
        for line in f:
            s = line.strip()
            if not s or s.startswith("%") or s.startswith("#"):
                continue
            parts = s.split()
            if len(parts) < 4:
                continue                     # no timestamp: not temporal
            srcs.append(int(parts[0]))
            dsts.append(int(parts[1]))
            times.append(int(float(parts[3])))
    if not srcs:
        raise SystemExit(f"{path}: no timestamped edges found")
    return (np.asarray(srcs, np.int64), np.asarray(dsts, np.int64),
            np.asarray(times, np.int64))


def densify_ids(src: np.ndarray,
                dst: np.ndarray) -> tuple[np.ndarray, np.ndarray, int]:
    """Remap (1-based, gappy) vertex ids to dense 0-based ids."""
    ids = np.unique(np.concatenate([src, dst]))
    return (np.searchsorted(ids, src), np.searchsorted(ids, dst),
            int(ids.shape[0]))


def bin_timestamps(t: np.ndarray, num_steps: int) -> np.ndarray:
    """Raw timestamps -> ``num_steps`` equal-width integer bins."""
    lo, hi = int(t.min()), int(t.max())
    span = max(hi - lo, 1)
    bins = ((t - lo).astype(np.float64) * num_steps / (span + 1))
    return np.minimum(bins.astype(np.int64), num_steps - 1)


def preprocess(raw: Path, out: Path, num_steps: int) -> None:
    """Raw KONECT edge list -> repo edge-list file (tsv or npz)."""
    from repro.run.data import write_edgelist

    src, dst, ts = parse_konect(raw)
    src, dst, n = densify_ids(src, dst)
    tb = bin_timestamps(ts, num_steps)
    order = np.argsort(tb, kind="stable")    # bin-major, file order kept
    edges = np.stack([src[order], dst[order]], axis=1).astype(np.int32)
    tb = tb[order]
    snaps = [edges[tb == k] for k in range(num_steps)]
    write_edgelist(out, snaps)
    print(f"{out}: {n} nodes, {edges.shape[0]} edges, "
          f"{num_steps} snapshots")


def sub_slice(src: np.ndarray, dst: np.ndarray, ts: np.ndarray,
              num_nodes: int
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Deterministic sub-slice: keep the first ``num_nodes`` distinct
    vertices in file order and the edges internal to them."""
    seen: dict[int, None] = {}
    for a, b in zip(src.tolist(), dst.tolist(), strict=True):
        if len(seen) >= num_nodes:
            break
        seen.setdefault(a)
        if len(seen) < num_nodes:
            seen.setdefault(b)
    keep_ids = np.asarray(sorted(seen), dtype=np.int64)
    mask = np.isin(src, keep_ids) & np.isin(dst, keep_ids)
    return src[mask], dst[mask], ts[mask]


def make_fixture(raw: Path, out: Path, num_nodes: int,
                 num_steps: int) -> None:
    """Tiny deterministic sub-slice -> committed offline fixture."""
    from repro.run.data import write_edgelist

    src, dst, ts = parse_konect(raw)
    src, dst, ts = sub_slice(src, dst, ts, num_nodes)
    if src.shape[0] == 0:
        raise SystemExit("sub-slice is empty; raise --num-nodes")
    src, dst, n = densify_ids(src, dst)
    tb = bin_timestamps(ts, num_steps)
    order = np.argsort(tb, kind="stable")
    edges = np.stack([src[order], dst[order]], axis=1).astype(np.int32)
    tb = tb[order]
    snaps = [edges[tb == k] for k in range(num_steps)]
    write_edgelist(out, snaps)
    print(f"{out}: {n} nodes, {edges.shape[0]} edges, "
          f"{num_steps} snapshots (deterministic sub-slice of {raw.name})")


# ------------------------------------------------------------ sample -------

def make_sample(out: Path, num_nodes: int = 120, num_edges: int = 900,
                seed: int = 20260807) -> None:
    """Deterministic KONECT-format sample (the offline stand-in the
    committed fixture derives from; format-faithful: 1-based gappy ids,
    signed weights, unix timestamps, % comment header)."""
    rng = np.random.default_rng(seed)
    # gappy 1-based id space, like real KONECT vertex columns
    ids = 1 + np.sort(rng.choice(num_nodes * 3, size=num_nodes,
                                 replace=False))
    src = ids[rng.integers(0, num_nodes, num_edges)]
    dst = ids[rng.integers(0, num_nodes, num_edges)]
    w = rng.choice([-1, 1], num_edges)
    t0 = 1_000_000_000
    ts = np.sort(rng.integers(t0, t0 + 10_000_000, num_edges))
    with open(out, "w") as f:
        f.write("% sym unweighted\n% deterministic sample "
                f"(tools/fetch_data.py sample, seed={seed})\n")
        for a, b, c, d in zip(src, dst, w, ts, strict=True):
            f.write(f"{a} {b} {c} {d}\n")
    print(f"{out}: {num_edges} rows, seed={seed}")


# --------------------------------------------------------------- CLI -------

def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    f = sub.add_parser("fetch", help="download + checksum + extract")
    f.add_argument("--dataset", choices=sorted(DATASETS), required=True)
    f.add_argument("--dest", type=Path, default=Path("data"))
    f.add_argument("--expect-sha256", default=None)
    f.add_argument("--num-steps", type=int, default=32)
    f.add_argument("--out", type=Path, default=None,
                   help="also preprocess to this edge-list file")

    p = sub.add_parser("preprocess", help="raw KONECT -> edge-list file")
    p.add_argument("--raw", type=Path, required=True)
    p.add_argument("--out", type=Path, required=True)
    p.add_argument("--num-steps", type=int, default=32)

    x = sub.add_parser("fixture", help="deterministic tiny sub-slice")
    x.add_argument("--raw", type=Path, required=True)
    x.add_argument("--out", type=Path, required=True)
    x.add_argument("--num-nodes", type=int, default=24)
    x.add_argument("--num-steps", type=int, default=8)

    s = sub.add_parser("sample", help="offline KONECT-format sample")
    s.add_argument("--out", type=Path, required=True)
    s.add_argument("--seed", type=int, default=20260807)

    a = ap.parse_args(argv)
    if a.cmd == "fetch":
        raw = fetch(DATASETS[a.dataset], a.dest, a.expect_sha256)
        if a.out is not None:
            preprocess(raw, a.out, a.num_steps)
    elif a.cmd == "preprocess":
        preprocess(a.raw, a.out, a.num_steps)
    elif a.cmd == "fixture":
        make_fixture(a.raw, a.out, a.num_nodes, a.num_steps)
    elif a.cmd == "sample":
        make_sample(a.out, seed=a.seed)


if __name__ == "__main__":
    main()
