#!/usr/bin/env python
"""Docs checker: markdown link integrity + executable examples.

Two jobs, both run by CI (the ``docs`` job) and by
``tests/test_docs.py`` so the documentation cannot rot:

* **link check** — every relative markdown link in README.md and
  docs/*.md must point at a file that exists in the repo (anchors into
  markdown targets are checked against the target's headings with
  GitHub's slug rules).  Links that resolve outside the repo root are
  web-relative (e.g. the CI badge) and skipped, as are absolute URLs.
* **example run** — every ```python fence in the EXAMPLE_DOCS files
  (docs/run_api.md, docs/serve_api.md, docs/sampling.md) executes, in
  file order, each
  file in its own shared interpreter namespace (later blocks may use
  earlier blocks' variables).  The blocks are written tiny so each file
  trains in seconds.

Usage: python tools/check_docs.py [--no-run]
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
EXAMPLE_DOCS = ("run_api.md", "serve_api.md", "sampling.md",
                "compression.md", "observability.md")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"^```python\n(.*?)^```", re.MULTILINE | re.DOTALL)


def doc_files() -> list[Path]:
    return [REPO / "README.md"] + sorted((REPO / "docs").glob("*.md"))


def github_slug(heading: str) -> str:
    """GitHub's heading -> anchor slug (enough of it for our docs)."""
    s = re.sub(r"`([^`]*)`", r"\1", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_links(files: list[Path] | None = None) -> list[str]:
    """-> list of 'file: broken link' problems (empty = all good)."""
    problems: list[str] = []
    for md in files or doc_files():
        text = md.read_text()
        for target in LINK_RE.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # http:, mailto:
                continue
            path_part, _, fragment = target.partition("#")
            if not path_part:                  # same-file anchor
                dest = md
            else:
                dest = (md.parent / path_part).resolve()
                if REPO not in dest.parents and dest != REPO:
                    continue                   # web-relative (CI badge)
                if not dest.exists():
                    problems.append(f"{md.relative_to(REPO)}: broken link "
                                    f"-> {target}")
                    continue
            if fragment and dest.suffix == ".md":
                slugs = {github_slug(h)
                         for h in HEADING_RE.findall(dest.read_text())}
                if fragment not in slugs:
                    problems.append(f"{md.relative_to(REPO)}: missing "
                                    f"anchor -> {target}")
    return problems


def python_blocks(md: Path) -> list[str]:
    return FENCE_RE.findall(md.read_text())


def run_examples(md: Path | None = None, verbose: bool = True) -> None:
    """Execute one doc's ```python blocks in one shared namespace;
    raises on the first failing block."""
    md = md or REPO / "docs" / "run_api.md"
    blocks = python_blocks(md)
    if not blocks:
        raise AssertionError(f"{md}: no python examples found")
    ns: dict = {"__name__": "__docs__"}
    for i, block in enumerate(blocks):
        if verbose:
            head = block.strip().splitlines()[0]
            print(f"[check_docs] {md.name} block {i + 1}/{len(blocks)}: "
                  f"{head}")
        exec(compile(block, f"{md.name}#block{i + 1}", "exec"), ns)  # noqa: S102


def run_all_examples(verbose: bool = True) -> None:
    """Execute every EXAMPLE_DOCS file, each in a fresh namespace."""
    for name in EXAMPLE_DOCS:
        run_examples(REPO / "docs" / name, verbose=verbose)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--no-run", action="store_true",
                    help="link check only, skip executing the examples")
    args = ap.parse_args()
    problems = check_links()
    for p in problems:
        print(f"[check_docs] FAIL {p}")
    if problems:
        return 1
    print(f"[check_docs] links OK across "
          f"{', '.join(f.name for f in doc_files())}")
    if not args.no_run:
        # the distributed example in run_api.md wants host devices; set
        # the flag before the first jax import
        os.environ.setdefault("XLA_FLAGS",
                              "--xla_force_host_platform_device_count=8")
        run_all_examples()
        print("[check_docs] examples OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
