"""dynlint — project-invariant static analysis for this repo.

``python -m tools.dynlint src/`` runs six AST passes encoding the
codebase's load-bearing invariants (donation safety, interpret-mode
discipline, PRNG hygiene, shard-spec consistency, static-shape
discipline, lock discipline).  See ``docs/invariants.md`` for the pass
catalogue, the historical bug each one encodes, and the pragma syntax.
"""

from tools.dynlint.core import Finding, Source, run

__all__ = ["Finding", "Source", "run"]
