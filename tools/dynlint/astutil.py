"""Small shared AST helpers for dynlint passes."""

from __future__ import annotations

import ast


def dotted(node: ast.AST) -> str | None:
    """'jax.random.PRNGKey' for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> str | None:
    return dotted(call.func)


def name_tail(name: str | None) -> str | None:
    return name.rsplit(".", 1)[-1] if name else None


def const_tuple(node: ast.AST) -> tuple[int, ...] | None:
    """Literal int or tuple-of-ints, else None."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, ast.Tuple):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return tuple(out)
    return None


def target_key(node: ast.AST) -> str | None:
    """A trackable lvalue/rvalue key: bare name or self attribute."""
    if isinstance(node, ast.Name):
        return node.id
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return f"self.{node.attr}"
    return None


def assigned_keys(stmt: ast.stmt) -> set[str]:
    """Keys (re)bound by one statement, including tuple targets."""
    keys: set[str] = set()

    def add(t: ast.AST) -> None:
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                add(e)
        else:
            k = target_key(t)
            if k:
                keys.add(k)

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            add(t)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        add(stmt.target)
    elif isinstance(stmt, ast.For):
        add(stmt.target)
    elif isinstance(stmt, ast.With):
        for item in stmt.items:
            if item.optional_vars is not None:
                add(item.optional_vars)
    return keys


def terminates(body: list[ast.stmt]) -> bool:
    """True if control flow never falls past this block's last stmt."""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Break, ast.Continue))


def is_jax_jit(node: ast.AST) -> bool:
    """True for `jax.jit` / `jit` references."""
    return name_tail(dotted(node)) == "jit"


def jit_call_info(call: ast.Call) -> tuple[bool, tuple[int, ...]]:
    """(is a jax.jit(...) call, donated argnums from a literal kwarg)."""
    if not is_jax_jit(call.func):
        return False, ()
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return True, const_tuple(kw.value) or ()
    return True, ()


def partial_jit_decorator(dec: ast.AST) -> tuple[bool, tuple[int, ...]]:
    """Decorator `@partial(jax.jit, donate_argnums=...)` or `@jax.jit`."""
    if is_jax_jit(dec):
        return True, ()
    if isinstance(dec, ast.Call):
        n = name_tail(call_name(dec))
        if n == "partial" and dec.args and is_jax_jit(dec.args[0]):
            for kw in dec.keywords:
                if kw.arg == "donate_argnums":
                    return True, const_tuple(kw.value) or ()
            return True, ()
        if is_jax_jit(dec.func):
            return True, ()
    return False, ()
