"""dynlint core: file walking, pragma handling, pass running.

A *pass* is a module in ``tools.dynlint.passes`` exposing

    PASS_ID: str            # stable id, also the pragma key
    check(src: Source) -> list[Finding]

Findings are suppressed by a pragma comment on the reported line or on
a comment line immediately above it::

    x = f(key)  # dynlint: allow[prng]

    # why this is deliberate ...
    # dynlint: allow[donation,prng]
    return self.edges

Pragmas name the passes they silence; ``allow[*]`` silences all.
"""

from __future__ import annotations

import ast
import json
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path

PRAGMA_RE = re.compile(r"#\s*dynlint:\s*allow\[([\w\s,*-]+)\]")


@dataclass(frozen=True)
class Finding:
    pass_id: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.pass_id}] {self.message}"

    def as_dict(self) -> dict:
        return {"pass": self.pass_id, "path": self.path, "line": self.line,
                "message": self.message}


@dataclass
class Source:
    """One parsed file handed to every pass."""

    path: str
    text: str
    tree: ast.AST
    lines: list[str] = field(default_factory=list)
    _allow: dict[int, set[str]] = field(default_factory=dict)

    @classmethod
    def parse(cls, path: str | Path) -> "Source":
        return cls.from_text(Path(path).read_text(), str(path))

    @classmethod
    def from_text(cls, text: str, path: str = "<fixture>.py") -> "Source":
        src = cls(path=path, text=text,
                  tree=ast.parse(text, filename=path),
                  lines=text.splitlines())
        src._allow = _collect_pragmas(src.lines)
        return src

    def allowed(self, pass_id: str, line: int) -> bool:
        allow = self._allow.get(line, ())
        return pass_id in allow or "*" in allow


def _collect_pragmas(lines: list[str]) -> dict[int, set[str]]:
    """1-based line -> pass ids allowed there.

    A pragma applies to its own line; pragmas on comment-only lines also
    flow down through the comment block onto the first code line below.
    """
    allow: dict[int, set[str]] = {}
    pending: set[str] = set()
    for i, raw in enumerate(lines, start=1):
        ids: set[str] = set()
        m = PRAGMA_RE.search(raw)
        if m:
            ids = {p.strip() for p in m.group(1).split(",") if p.strip()}
            allow.setdefault(i, set()).update(ids)
        stripped = raw.strip()
        if stripped.startswith("#"):
            pending |= ids
        else:
            if pending and stripped:
                allow.setdefault(i, set()).update(pending)
            if stripped:
                pending = set()
    return allow


def iter_py_files(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(f for f in path.rglob("*.py")
                              if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            out.append(path)
        else:
            raise FileNotFoundError(f"not a python file or directory: {p}")
    return out


def load_passes(select: list[str] | None = None):
    from tools.dynlint.passes import ALL_PASSES
    if select is None:
        return list(ALL_PASSES)
    by_id = {p.PASS_ID: p for p in ALL_PASSES}
    unknown = [s for s in select if s not in by_id]
    if unknown:
        raise KeyError(f"unknown pass id(s) {unknown}; "
                       f"have {sorted(by_id)}")
    return [by_id[s] for s in select]


def run(paths: list[str], select: list[str] | None = None
        ) -> list[Finding]:
    passes = load_passes(select)
    findings: list[Finding] = []
    for f in iter_py_files(paths):
        try:
            src = Source.parse(f)
        except SyntaxError as e:
            findings.append(Finding("parse", str(f), e.lineno or 0,
                                    f"syntax error: {e.msg}"))
            continue
        for p in passes:
            for fd in p.check(src):
                if not src.allowed(fd.pass_id, fd.line):
                    findings.append(fd)
    findings.sort(key=lambda fd: (fd.path, fd.line, fd.pass_id))
    return findings


def main(argv: list[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m tools.dynlint",
        description="project-invariant static analysis "
                    "(see docs/invariants.md)")
    ap.add_argument("paths", nargs="+", help="files or directories")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", default=None,
                    help="comma-separated pass ids (default: all)")
    args = ap.parse_args(argv)
    select = args.select.split(",") if args.select else None
    findings = run(args.paths, select)
    if args.format == "json":
        print(json.dumps([fd.as_dict() for fd in findings], indent=2))
    else:
        for fd in findings:
            print(fd.render())
        n_passes = len(load_passes(select))
        print(f"dynlint: {len(findings)} finding(s), "
              f"{n_passes} pass(es)", file=sys.stderr)
    return 1 if findings else 0
