"""Timing-discipline pass.

Invariant (PR 10): performance timing inside ``src/`` goes through
``repro.obs`` — ``obs.span`` / ``obs.stopwatch`` for measured regions,
``obs.now_s`` for point timestamps — so every measurement lands on one
clock, shows up in exported traces, and disappears when the tracer is
off.  Raw monotonic-clock reads (``time.perf_counter[_ns]`` /
``time.monotonic[_ns]``) scattered through the code produce numbers no
trace can see and no calibration can join.

Flagged: any call to those four functions in ``src/`` files, whether
via the module (``time.perf_counter()``, including ``import time as
t``) or a from-import (``from time import perf_counter as pc``).
Exempt by construction: ``repro/obs/`` (the clock's one home) and
``repro/ft/`` (the StepTimer context-manager is the sanctioned raw
consumer, and ft must import nothing heavy).  ``time.time()`` is NOT
flagged — wall-clock provenance stamps are legitimate.

Deliberate exceptions carry ``# dynlint: allow[timing]``.
"""

from __future__ import annotations

import ast
from pathlib import PurePath

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "timing"

_CLOCK_FNS = ("perf_counter", "perf_counter_ns",
              "monotonic", "monotonic_ns")
_EXEMPT_PARTS = ("obs", "ft", "tests", "examples")


def _in_scope(path: str) -> bool:
    parts = PurePath(path).parts
    if "src" not in parts:
        return False
    return not any(p in parts for p in _EXEMPT_PARTS)


def _clock_names(tree: ast.AST) -> tuple[set[str], set[str]]:
    """(module aliases of ``time``, local names bound to clock fns)."""
    mods: set[str] = set()
    fns: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "time":
                    mods.add(alias.asname or "time")
        elif isinstance(node, ast.ImportFrom) and node.module == "time":
            for alias in node.names:
                if alias.name in _CLOCK_FNS:
                    fns.add(alias.asname or alias.name)
    return mods, fns


def check(src: Source) -> list[Finding]:
    if not _in_scope(src.path):
        return []
    mods, fns = _clock_names(src.tree)
    if not mods and not fns:
        return []
    out: list[Finding] = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        full = au.call_name(node)
        if full is None:
            continue
        hit = None
        if "." in full:
            mod, tail = full.rsplit(".", 1)
            if mod in mods and tail in _CLOCK_FNS:
                hit = tail
        elif full in fns:
            hit = full
        if hit is not None:
            out.append(Finding(
                PASS_ID, src.path, node.lineno,
                f"raw {hit}() read — route timing through repro.obs "
                "(obs.stopwatch for measured regions, obs.span for "
                "traced phases, obs.now_s for point timestamps) so it "
                "lands on the tracer clock; deliberate raw reads carry "
                "`# dynlint: allow[timing]`"))
    return out
