"""Interpret-mode discipline pass.

Invariant (PR 2 incident): Pallas call sites must route their interpret
flag through ``kernels.common.resolve_interpret`` so the env override
and backend probing stay in one place — a literal ``interpret=True``
left behind from debugging silently runs the kernel in interpret mode
on real backends; a literal ``False`` breaks hosts without a compiled
lowering.  Only ``kernels/common.py`` itself may spell the literal.
"""

from __future__ import annotations

import ast

from tools.dynlint.core import Finding, Source

PASS_ID = "interpret"

EXEMPT_SUFFIXES = ("kernels/common.py",)


def check(src: Source) -> list[Finding]:
    norm = src.path.replace("\\", "/")
    if norm.endswith(EXEMPT_SUFFIXES):
        return []
    out: list[Finding] = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if (kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and isinstance(kw.value.value, bool)):
                out.append(Finding(
                    PASS_ID, src.path, kw.value.lineno,
                    f"literal interpret={kw.value.value} at a call site — "
                    "thread the flag through "
                    "kernels.common.resolve_interpret() so env override "
                    "and backend probing apply"))
    return out
