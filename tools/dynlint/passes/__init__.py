"""Pass registry.  Order is the order findings are attributed in."""

from tools.dynlint.passes import (donation, interpret_mode, locks, prng,
                                  shard_axes, static_shapes, timing)

ALL_PASSES = (
    donation,
    interpret_mode,
    prng,
    shard_axes,
    static_shapes,
    locks,
    timing,
)

__all__ = ["ALL_PASSES"]
