"""Donation-safety pass.

Invariant: a buffer passed at a donated argument position of a jitted
call must not be read again in the same scope unless it was rebound
from the call's result first.  On host CPU donation is a no-op, so the
bug class trains fine locally and corrupts state only on accelerators
(the ``DeltaApplier`` ring / resident-carry incidents).

Donating callables are recognized from:

* ``NAME = jax.jit(f, donate_argnums=...)`` (module or class scope)
* ``@partial(jax.jit, donate_argnums=...)`` decorated functions
* ``sanitize.guard_donated(f, argnums)`` wrappers
* factory calls registered in ``DONATING_FACTORIES`` (functions that
  RETURN a donating step, e.g. ``serve.state.make_advance_step``)
* either arm of a conditional expression being donating

Additionally, a method that donates one of its ``self`` attributes and
*returns* that same attribute is flagged: the returned alias is
invalidated by the next call (the ring contract) — pragma the return if
the aliasing is documented API.
"""

from __future__ import annotations

import ast

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "donation"

# factory function -> donate_argnums of the callable it returns
DONATING_FACTORIES = {
    "make_advance_step": (1,),
}


def _donation_of_value(value: ast.AST, env: dict[str, tuple[int, ...]]
                       ) -> tuple[int, ...] | None:
    """Donated argnums if `value` evaluates to a donating callable."""
    if isinstance(value, ast.IfExp):
        return (_donation_of_value(value.body, env)
                or _donation_of_value(value.orelse, env))
    key = au.target_key(value)
    if key is not None:
        return env.get(key)
    if not isinstance(value, ast.Call):
        return None
    is_jit, nums = au.jit_call_info(value)
    if is_jit and nums:
        return nums
    name = au.name_tail(au.call_name(value))
    if name == "guard_donated" and len(value.args) >= 2:
        return au.const_tuple(value.args[1])
    if name in DONATING_FACTORIES:
        return DONATING_FACTORIES[name]
    return None


def _collect_env(tree: ast.AST) -> dict[str, tuple[int, ...]]:
    """Map of donating callables: names, self-attrs, decorated defs."""
    env: dict[str, tuple[int, ...]] = {}

    class V(ast.NodeVisitor):
        def visit_Assign(self, node: ast.Assign) -> None:
            nums = _donation_of_value(node.value, env)
            if nums:
                for t in node.targets:
                    k = au.target_key(t)
                    if k:
                        env[k] = nums
            self.generic_visit(node)

        def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
            for dec in node.decorator_list:
                ok, nums = au.partial_jit_decorator(dec)
                if ok and nums:
                    env[node.name] = nums
            self.generic_visit(node)

    V().visit(tree)
    return env


class _Flow:
    """Poison-set walk over one function body."""

    def __init__(self, src: Source, env: dict[str, tuple[int, ...]]):
        self.src = src
        self.env = env
        self.findings: list[Finding] = []
        self.donated_attrs: set[str] = set()

    def _loads(self, node: ast.AST) -> list[tuple[str, int]]:
        out = []
        for n in ast.walk(node):
            k = au.target_key(n)
            if k and isinstance(getattr(n, "ctx", None), ast.Load):
                out.append((k, n.lineno))
        return out

    def _donations(self, stmt: ast.stmt) -> list[tuple[str, int]]:
        """(key, line) for donated Name/self-attr args in this stmt."""
        out = []
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            fn_key = au.target_key(node.func)
            nums = self.env.get(fn_key) if fn_key else None
            if nums is None and isinstance(node.func, ast.Name):
                nums = self.env.get(node.func.id)
            if not nums:
                continue
            for i in nums:
                if i < len(node.args):
                    k = au.target_key(node.args[i])
                    if k:
                        out.append((k, node.lineno))
                        if k.startswith("self."):
                            self.donated_attrs.add(k)
        return out

    def run(self, body: list[ast.stmt], poison: dict[str, int]
            ) -> dict[str, int]:
        for stmt in body:
            poison = self.step(stmt, poison)
        return poison

    def step(self, stmt: ast.stmt, poison: dict[str, int]
             ) -> dict[str, int]:
        if isinstance(stmt, ast.If):
            a = self.run(stmt.body, dict(poison))
            b = self.run(stmt.orelse, dict(poison))
            # a branch that returns/raises never reaches the code below
            ta, tb = au.terminates(stmt.body), au.terminates(stmt.orelse)
            if ta and tb:
                return poison
            if ta:
                return b
            if tb:
                return a
            return {**a, **b}
        if isinstance(stmt, (ast.For, ast.While)):
            p = dict(poison)
            for k in au.assigned_keys(stmt):
                p.pop(k, None)
            p = self.run(stmt.body, p)
            # second pass: catches donate-at-end-of-body / read-at-top
            self.run(stmt.body, dict(p))
            return self.run(stmt.orelse, {**poison, **p})
        if isinstance(stmt, (ast.With, ast.Try)):
            p = dict(poison)
            for blk in ("body", "orelse", "finalbody"):
                p = self.run(getattr(stmt, blk, []) or [], p)
            for h in getattr(stmt, "handlers", []) or []:
                p = self.run(h.body, p)
            return p
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return poison          # nested scopes analyzed separately
        return self._stmt(stmt, poison)

    def _stmt(self, stmt: ast.stmt, poison: dict[str, int]
              ) -> dict[str, int]:
        # 1) reads of already-poisoned keys are violations
        for key, line in self._loads(stmt):
            if key in poison:
                self.findings.append(Finding(
                    PASS_ID, self.src.path, line,
                    f"'{key}' was donated to a jitted call on line "
                    f"{poison[key]} and read again without being rebound "
                    "from the call's result"))
                poison = {k: v for k, v in poison.items() if k != key}
        # 2) this stmt's donations poison their args ...
        for key, line in self._donations(stmt):
            poison = {**poison, key: line}
        # 3) ... except keys the stmt rebinds (result rebinding)
        for key in au.assigned_keys(stmt):
            poison.pop(key, None)
        return poison


def _check_return_alias(flow: _Flow, fn: ast.FunctionDef, src: Source
                        ) -> list[Finding]:
    if not flow.donated_attrs:
        return []
    out = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Return) and node.value is not None:
            for sub in ast.walk(node.value):
                k = au.target_key(sub)
                if k in flow.donated_attrs:
                    out.append(Finding(
                        PASS_ID, src.path, node.lineno,
                        f"returns '{k}', an alias of a buffer this method "
                        "donates — the next call invalidates the returned "
                        "value (callers must copy first); pragma if this "
                        "ring contract is documented API"))
    return out


def check(src: Source) -> list[Finding]:
    env = _collect_env(src.tree)
    findings: list[Finding] = []
    for node in ast.walk(src.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        flow = _Flow(src, env)
        flow.run(node.body, {})
        findings.extend(flow.findings)
        if isinstance(node, ast.FunctionDef):
            findings.extend(_check_return_alias(flow, node, src))
    # the loop double-pass can report the same read twice
    seen: set[tuple[int, str]] = set()
    return [fd for fd in findings
            if (fd.line, fd.message) not in seen
            and not seen.add((fd.line, fd.message))]
