"""Lock-discipline pass (static race detector for the threading layer).

Invariant: an attribute mutated from a ``threading.Thread`` target must
be written under a held lock (``with self.<lock>:`` where ``<lock>`` is
assigned from ``threading.Lock``/``RLock``) or be declared in the
class's ``_thread_owned`` allowlist with a comment explaining the
synchronization edge (e.g. ``PrefetchIterator._err``: the queue
sentinel is the happens-before edge).

Thread targets are resolved per class (``target=self.<method>``) and
per enclosing function (``target=<local closure>``).  Writes through
method calls (``self._q.put(...)``) are not attribute stores and are
the queue's own problem.
"""

from __future__ import annotations

import ast

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "locks"


def _thread_target(call: ast.Call) -> ast.AST | None:
    if au.name_tail(au.call_name(call)) != "Thread":
        return None
    for kw in call.keywords:
        if kw.arg == "target":
            return kw.value
    return None


def _lock_attrs(cls: ast.ClassDef) -> set[str]:
    """self attrs assigned threading.Lock()/RLock() anywhere in the class."""
    out: set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if au.name_tail(au.call_name(node.value)) in ("Lock", "RLock"):
                for t in node.targets:
                    k = au.target_key(t)
                    if k and k.startswith("self."):
                        out.add(k.split(".", 1)[1])
    return out


def _thread_owned(cls: ast.ClassDef) -> set[str]:
    """Names in the class-level ``_thread_owned`` tuple/list/set."""
    for stmt in cls.body:
        targets = []
        if isinstance(stmt, ast.Assign):
            targets = stmt.targets
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets = [stmt.target]
        if not any(isinstance(t, ast.Name) and t.id == "_thread_owned"
                   for t in targets):
            continue
        value = stmt.value
        if isinstance(value, (ast.Tuple, ast.List, ast.Set)):
            return {e.value for e in value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)}
    return set()


def _held_lock_writes(fn: ast.AST, locks: set[str]
                      ) -> dict[int, bool]:
    """id(node) -> True for self-attr Stores under `with self.<lock>:`."""
    held: dict[int, bool] = {}

    def visit(node: ast.AST, under: bool) -> None:
        if isinstance(node, ast.With):
            locked = under or any(
                (au.target_key(item.context_expr) or "")
                .removeprefix("self.") in locks
                for item in node.items)
            for child in ast.iter_child_nodes(node):
                visit(child, locked)
            return
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Store):
            held[id(node)] = under
        for child in ast.iter_child_nodes(node):
            visit(child, under)

    visit(fn, False)
    return held


def _check_target(cls_name: str, fn: ast.AST, locks: set[str],
                  owned: set[str], src: Source) -> list[Finding]:
    out = []
    held = _held_lock_writes(fn, locks)
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Attribute)
                and isinstance(node.ctx, ast.Store)
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"):
            continue
        if node.attr in owned or held.get(id(node), False):
            continue
        out.append(Finding(
            PASS_ID, src.path, node.lineno,
            f"'self.{node.attr}' is written from a threading.Thread "
            f"target of {cls_name} without a held lock — wrap in `with "
            "self.<lock>:` or declare it in the class's _thread_owned "
            "allowlist with the synchronization argument"))
    return out


def check(src: Source) -> list[Finding]:
    out: list[Finding] = []
    for cls in ast.walk(src.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        locks = _lock_attrs(cls)
        owned = _thread_owned(cls)
        methods = {m.name: m for m in cls.body
                   if isinstance(m, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))}
        for node in ast.walk(cls):
            if not isinstance(node, ast.Call):
                continue
            target = _thread_target(node)
            if target is None:
                continue
            # target=self.<method>
            key = au.target_key(target)
            if key and key.startswith("self."):
                m = methods.get(key.split(".", 1)[1])
                if m is not None:
                    out.extend(_check_target(cls.name, m, locks, owned,
                                             src))
            # target=<local closure defined in the same method>
            elif isinstance(target, ast.Name):
                for m in methods.values():
                    for sub in ast.walk(m):
                        if isinstance(sub, ast.FunctionDef) and \
                                sub.name == target.id:
                            out.extend(_check_target(
                                cls.name, sub, locks, owned, src))
    return out
