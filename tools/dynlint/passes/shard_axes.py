"""Shard-spec consistency pass.

Invariant: mesh-axis names in ``PartitionSpec`` / ``P`` constructions
and collective calls must be spelled through the canonical constants in
``repro.dist.sharding`` (``DATA_AXIS``/``MODEL_AXIS``/``POD_AXIS``) or
arrive as variables — never as inline string literals.  A typo'd
literal axis silently replicates the dimension (PartitionSpec validates
against the mesh only at sharding time, far from the spec); constants
fail at import.

Function-parameter *defaults* (``axis: str = "data"``) are allowed:
they name the convention once, and call sites pass variables.
``dist/sharding.py`` itself defines the constants.
"""

from __future__ import annotations

import ast

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "shard_axes"

_SPEC_NAMES = {"P", "PartitionSpec"}
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "psum_scatter",
                "all_to_all", "all_gather", "axis_index", "ppermute",
                "pshuffle", "axis_size"}


def _default_ranges(tree: ast.AST) -> set[int]:
    """id()s of nodes inside function-signature defaults (exempt)."""
    out: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            defaults = list(node.args.defaults) + [
                d for d in node.args.kw_defaults if d is not None]
            for d in defaults:
                for sub in ast.walk(d):
                    out.add(id(sub))
    return out


def _string_literals(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub


def check(src: Source) -> list[Finding]:
    out: list[Finding] = []
    exempt = _default_ranges(src.tree)
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        name = au.name_tail(au.call_name(node))
        if name in _SPEC_NAMES:
            where = "PartitionSpec"
        elif name in _COLLECTIVES:
            where = f"collective {name}()"
        else:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            for lit in _string_literals(arg):
                if id(lit) in exempt:
                    continue
                out.append(Finding(
                    PASS_ID, src.path, lit.lineno,
                    f"axis name {lit.value!r} spelled as a string literal "
                    f"in {where} — use the mesh-axis constants from "
                    "repro.dist.sharding (DATA_AXIS/MODEL_AXIS/POD_AXIS)"))
    return out
