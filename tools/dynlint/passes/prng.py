"""PRNG hygiene pass.

Two rules (PR 3 incident: hard-coded ``PRNGKey(0)`` hid seed plumbing
regressions for three PRs):

* **literal keys** — ``PRNGKey(<int literal>)`` / ``jax.random.key(<int
  literal>)`` is banned outside tests/examples; thread the run seed.
  Deliberate shape-only / dry-run keys carry ``# dynlint: allow[prng]``.
* **key reuse** — a key variable passed as a call argument twice in one
  scope without an intervening ``split``/``fold_in`` rebinding produces
  correlated randomness.  Branches of an ``if`` merge by max use count;
  a single consuming use inside a loop body counts as reuse (it repeats
  every iteration).  Nested ``def``/``lambda`` bodies are separate
  scopes.
"""

from __future__ import annotations

import ast

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "prng"

_SPLITTERS = {"split", "fold_in"}
_EXEMPT_PARTS = ("tests", "examples")


def _is_key_call(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    full = au.call_name(node) or ""
    if au.name_tail(full) == "PRNGKey":
        return True
    # key/split/fold_in only under jax.random — `jnp.split` splits
    # arrays, not keys, and bare `key(...)`/`split(...)` are too common
    return any(full.endswith(f"random.{n}")
               for n in ("key", "split", "fold_in"))


def _literal_key_findings(src: Source) -> list[Finding]:
    out = []
    for node in ast.walk(src.tree):
        if not isinstance(node, ast.Call):
            continue
        name = au.name_tail(au.call_name(node))
        full = au.call_name(node) or ""
        is_maker = (name == "PRNGKey"
                    or full.endswith("random.key"))
        if (is_maker and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, int)):
            out.append(Finding(
                PASS_ID, src.path, node.lineno,
                f"hard-coded {name}({node.args[0].value}) — thread the "
                "run seed (RunConfig.seed / ServeConfig.seed) instead"))
    return out


class _Reuse:
    """Per-scope consuming-use counts for key variables."""

    def __init__(self, src: Source):
        self.src = src
        self.findings: list[Finding] = []

    def scope(self, body: list[ast.stmt],
              params: tuple[str, ...] | set[str] = ()) -> None:
        self._block(body, {p: 0 for p in params})

    def _block(self, body: list[ast.stmt], uses: dict[str, int]
               ) -> dict[str, int]:
        for stmt in body:
            uses = self._stmt(stmt, uses)
        return uses

    def _stmt(self, stmt: ast.stmt, uses: dict[str, int]) -> dict[str, int]:
        if isinstance(stmt, ast.If):
            a = self._block(stmt.body, dict(uses))
            b = self._block(stmt.orelse, dict(uses))
            # a branch that returns/raises never reaches the code below
            ta, tb = au.terminates(stmt.body), au.terminates(stmt.orelse)
            if ta and tb:
                return uses
            if ta:
                return b
            if tb:
                return a
            keys = set(a) | set(b)
            return {k: max(a.get(k, 0), b.get(k, 0)) for k in keys}
        if isinstance(stmt, (ast.For, ast.While)):
            u = self._block(stmt.body, dict(uses))
            u = self._block(stmt.body, u)   # loop repeats its body
            return self._block(stmt.orelse, u)
        if isinstance(stmt, (ast.With, ast.Try)):
            for blk in ("body", "orelse", "finalbody"):
                uses = self._block(getattr(stmt, blk, []) or [], uses)
            for h in getattr(stmt, "handlers", []) or []:
                uses = self._block(h.body, uses)
            return uses
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return uses             # separate scope
        return self._linear(stmt, uses)

    def _linear(self, stmt: ast.stmt, uses: dict[str, int]
                ) -> dict[str, int]:
        # count consuming uses: key names appearing as call args of
        # non-splitting calls.  Count NAME OCCURRENCES (node ids), not
        # per enclosing call — g(f(key)) is one use, f(key, key) two.
        # `keys[i]` picks a distinct subkey, so a subscripted name is
        # not a use of the whole array.
        subscripted: set[int] = set()
        for node in self._walk_scope(stmt):
            if isinstance(node, ast.Subscript) and \
                    isinstance(node.value, ast.Name):
                subscripted.add(id(node.value))
        counted: set[int] = set()
        for node in self._walk_scope(stmt):
            if not isinstance(node, ast.Call):
                continue
            fn_name = au.name_tail(au.call_name(node)) or ""
            if fn_name in _SPLITTERS:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                for sub in ast.walk(arg):
                    if (isinstance(sub, ast.Name) and sub.id in uses
                            and id(sub) not in counted
                            and id(sub) not in subscripted):
                        counted.add(id(sub))
                        uses[sub.id] += 1
                        if uses[sub.id] == 2:
                            self.findings.append(Finding(
                                PASS_ID, self.src.path, sub.lineno,
                                f"key '{sub.id}' passed to a second "
                                "consumer without an intervening "
                                "jax.random.split/fold_in — correlated "
                                "randomness"))
        # rebindings: fresh key vars enter tracking, others leave
        for tgt, val in self._assignments(stmt):
            if self._is_key_value(val):
                uses[tgt] = 0
            else:
                uses.pop(tgt, None)
        return uses

    @staticmethod
    def _walk_scope(stmt: ast.stmt):
        skip: set[int] = set()
        for node in ast.walk(stmt):
            if id(node) in skip:
                continue
            if isinstance(node, (ast.Lambda, ast.FunctionDef,
                                 ast.AsyncFunctionDef)):
                for sub in ast.walk(node):
                    if sub is not node:
                        skip.add(id(sub))
                continue
            yield node

    @staticmethod
    def _is_key_value(val: ast.AST) -> bool:
        if _is_key_call(val):
            return True
        if isinstance(val, ast.Subscript) and _is_key_call(val.value):
            return True             # split(key)[0]
        return False

    @staticmethod
    def _assignments(stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    yield t.id, stmt.value
                elif isinstance(t, (ast.Tuple, ast.List)) and \
                        _is_key_call(stmt.value):
                    # key, sub = split(key): every element is a key
                    for e in t.elts:
                        if isinstance(e, ast.Name):
                            yield e.id, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None \
                and isinstance(stmt.target, ast.Name):
            yield stmt.target.id, stmt.value


def check(src: Source) -> list[Finding]:
    norm = src.path.replace("\\", "/")
    if any(part in norm.split("/") for part in _EXEMPT_PARTS):
        return []
    findings = _literal_key_findings(src)
    scopes: list[tuple[list[ast.stmt], set[str]]] = [(src.tree.body, set())]
    for node in ast.walk(src.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # parameters that are PRNG keys by naming convention join
            # the tracked set with zero uses
            keyish = {a.arg for a in (node.args.posonlyargs + node.args.args
                                      + node.args.kwonlyargs)
                      if a.arg in ("key", "rng") or a.arg.endswith("_key")}
            scopes.append((node.body, keyish))
    for body, params in scopes:
        r = _Reuse(src)
        r.scope(body, params)
        findings.extend(r.findings)
    # loop double-pass can duplicate
    seen: set[tuple[int, str]] = set()
    return [fd for fd in findings
            if (fd.line, fd.message) not in seen
            and not seen.add((fd.line, fd.message))]
