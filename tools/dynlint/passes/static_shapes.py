"""Static-shape discipline pass.

Invariant: functions that trace under ``jax.jit`` / ``shard_map`` must
stay abstract — host syncs and data-dependent Python control flow
either fail at trace time (opaquely, deep in a stack) or silently
de-optimize by forcing a device round-trip per step:

* ``.item()`` / ``int(tracer)`` / ``float(tracer)`` concretize
* ``np.asarray`` / ``np.array`` on a tracer forces a host transfer
  (``jnp.asarray`` is fine — it stays on device)
* ``jax.block_until_ready`` inside a traced body is a host sync
* a Python ``if`` on a function parameter of a directly-jitted
  function branches on traced data (use ``jnp.where``/``lax.cond``)

Traced scopes: ``@jax.jit`` / ``@partial(jax.jit, ...)`` decorated
defs, local defs passed to ``jax.jit(...)`` / ``shard_map(...)``, and
the helpers in ``TRACED_HELPERS`` (functions only ever called from
inside traced code, where the decorator is out of sight).
"""

from __future__ import annotations

import ast

from tools.dynlint import astutil as au
from tools.dynlint.core import Finding, Source

PASS_ID = "static_shapes"

# called only from inside jitted bodies; treat as traced
TRACED_HELPERS = {
    "advance_slice", "slice_weights_with_loops", "slice_nll",
    "snapshot_block_body", "_sp_block_body", "hybrid_spmm",
}

_NP_ROOTS = {"np", "numpy"}


def _jitted_names(tree: ast.AST) -> set[str]:
    """Local function names passed to jax.jit(...) or shard_map(...)."""
    out: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = au.name_tail(au.call_name(node))
        if name in ("jit", "shard_map") and node.args:
            if isinstance(node.args[0], ast.Name):
                out.add(node.args[0].id)
    return out


def _static_params(fn: ast.AST) -> set[str]:
    """Params marked static via the jit decorator's static_argnames /
    static_argnums — Python values at trace time, free to branch on."""
    out: set[str] = set()
    pos = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) and \
                            isinstance(sub.value, str):
                        out.add(sub.value)
            elif kw.arg == "static_argnums":
                nums = au.const_tuple(kw.value) or ()
                out.update(pos[i] for i in nums if i < len(pos))
    return out


def _traced_functions(tree: ast.AST):
    """(FunctionDef, directly_jitted: bool) for every traced scope."""
    by_call = _jitted_names(tree)
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        direct = any(au.partial_jit_decorator(d)[0]
                     for d in node.decorator_list)
        if direct or node.name in by_call:
            yield node, True
        elif node.name in TRACED_HELPERS:
            yield node, False


def _scope_nodes(fn: ast.AST):
    """Walk fn's body without descending into nested defs/lambdas."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def check(src: Source) -> list[Finding]:
    out: list[Finding] = []
    for fn, direct in _traced_functions(src.tree):
        params = {a.arg for a in (fn.args.posonlyargs + fn.args.args
                                  + fn.args.kwonlyargs)}
        params -= _static_params(fn)
        for node in _scope_nodes(fn):
            if isinstance(node, ast.Call):
                name = au.call_name(node) or ""
                tail = au.name_tail(name)
                if tail == "item" and isinstance(node.func, ast.Attribute):
                    out.append(Finding(
                        PASS_ID, src.path, node.lineno,
                        ".item() inside a traced function concretizes a "
                        "tracer — keep the value on device"))
                elif tail in ("asarray", "array") and \
                        name.split(".")[0] in _NP_ROOTS:
                    out.append(Finding(
                        PASS_ID, src.path, node.lineno,
                        f"{name}() inside a traced function forces a host "
                        "transfer — use jnp.asarray"))
                elif tail == "block_until_ready":
                    out.append(Finding(
                        PASS_ID, src.path, node.lineno,
                        "block_until_ready inside a traced function is a "
                        "host sync — sync at the call site instead"))
                elif tail in ("int", "float") and name == tail and \
                        node.args and not isinstance(node.args[0],
                                                     ast.Constant):
                    out.append(Finding(
                        PASS_ID, src.path, node.lineno,
                        f"{tail}() on a non-literal inside a traced "
                        "function concretizes a tracer — use "
                        "astype/jnp casts"))
            elif direct and isinstance(node, ast.If):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Name) and sub.id in params:
                        out.append(Finding(
                            PASS_ID, src.path, node.lineno,
                            f"Python `if` on parameter '{sub.id}' of a "
                            "jitted function branches on traced data — "
                            "use jnp.where or lax.cond"))
                        break
    return out
