import sys

from tools.dynlint.core import main

sys.exit(main())
